"""Fig. 3/4 reproduction: image blending + Gaussian smoothing quality.

  Fig 3 — multiplicative blending of two images with approximate
          multipliers; PSNR vs the accurate-multiplier result.
          Paper: SIMDive 46.6 dB vs MBM 32.1 dB (average).
  Fig 4 — Gaussian smoothing where the kernel-sum normalization uses the
          approximate *divider* (and a hybrid mode where multiplies are
          approximate too). PSNR vs accurate pipeline.
          Paper: div-only SIMDive 24.5 vs INZeD 20.9; hybrid 23.3 vs 21.3.

Images: USC-SIPI is not available offline — deterministic synthetic photos
(smoothed multi-scale noise, full 8-bit dynamic range) stand in; PSNR
*orderings* are the reproduced claim. PSNR/SSIM come from
:mod:`repro.metrics`; SIMDive/Mitchell arithmetic dispatches through the
kernel registry; the constant-correction competitors live in
:mod:`repro.core.baselines`.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import SimdiveSpec
from repro.core.baselines import const_corr_op
from repro.kernels import get_op
from repro.metrics import psnr, ssim


def synth_image(seed, hw=256):
    rng = np.random.default_rng(seed)
    img = np.zeros((hw, hw))
    for scale in (4, 8, 16, 32, 64):
        base = rng.normal(size=(hw // scale + 1, hw // scale + 1))
        up = np.kron(base, np.ones((scale, scale)))[:hw, :hw]
        img += up * scale
    img = (img - img.min()) / np.ptp(img)
    return (img * 255).astype(np.uint32)


def blend(img1, img2, mul):
    """Multiplicative blend: out = (img1 * img2) / 255."""
    p = mul(jnp.asarray(img1.ravel()), jnp.asarray(img2.ravel()))
    out = np.asarray(p).astype(np.float64) / 255.0
    return np.clip(out.reshape(img1.shape), 0, 255)


# classic 5x5 integer Gaussian (sigma~1); sum = 273 — deliberately NOT a
# power of two, so the normalization genuinely exercises the divider
GAUSS = np.asarray([
    [1, 4, 7, 4, 1],
    [4, 16, 26, 16, 4],
    [7, 26, 41, 26, 7],
    [4, 16, 26, 16, 4],
    [1, 4, 7, 4, 1]], np.uint32)
FO = 8  # divider fixed-point output bits


def gaussian(img, mul, div):
    """5x5 Gaussian: weighted sum via ``mul``, normalization via ``div``."""
    H, W = img.shape
    acc = np.zeros((H - 4, W - 4), np.uint64)
    for dy in range(5):
        for dx in range(5):
            patch = img[dy:dy + H - 4, dx:dx + W - 4]
            w = int(GAUSS[dy, dx])
            p = mul(jnp.asarray(patch.ravel()),
                    jnp.full(patch.size, w, jnp.uint32))
            acc += np.asarray(p).astype(np.uint64).reshape(patch.shape)
    den = int(GAUSS.sum())
    q = div(jnp.asarray(acc.astype(np.uint32).ravel()),
            jnp.full(acc.size, den, jnp.uint32))
    out = np.asarray(q).astype(np.float64).reshape(acc.shape) / 2.0 ** FO
    return np.clip(out, 0, 255)


def make_ops(backend="ref"):
    """Fig. 3/4 multiplier/divider families, registry-dispatched."""
    spec = SimdiveSpec(width=16, coeff_bits=6)
    mit = SimdiveSpec(width=16, coeff_bits=0, round_output=False)
    sd = get_op("elemwise", spec, backend)
    mt = get_op("elemwise", mit, backend)
    muls = {
        "accurate": lambda a, b: a.astype(jnp.uint32) * b,
        "simdive": lambda a, b: sd(a, b, op="mul"),
        "mitchell": lambda a, b: mt(a, b, op="mul"),
        "mbm-const": const_corr_op("mul", 16),
    }
    divs = {
        # exact baseline: 5x5 sums stay under 2^25 after << FO, so the
        # uint32 downcast without x64 is lossless
        # simdive-lint: allow(unguarded-uint64): exact baseline fits 32 bits
        "accurate": lambda a, b: ((a.astype(jnp.uint64) << FO)
                                  # simdive-lint: allow(unguarded-uint64): see above
                                  // b.astype(jnp.uint64)).astype(jnp.uint32),
        "simdive": lambda a, b: sd(a, b, op="div", frac_out=FO),
        "mitchell": lambda a, b: mt(a, b, op="div", frac_out=FO),
        "inzed-const": lambda a, b: const_corr_op("div", 16)(a, b, FO),
    }
    return muls, divs


def main(report=print, quick=False):
    muls, divs = make_ops()
    rows = {}

    i1, i2 = synth_image(1), synth_image(2)
    ref_blend = blend(i1, i2, muls["accurate"])
    report("fig3,design,PSNR-dB,SSIM (blending; paper: simdive 46.6, mbm 32.1)")
    for name in ("simdive", "mitchell", "mbm-const"):
        out = blend(i1, i2, muls[name])
        rows[f"fig3/{name}"] = {"psnr_db": psnr(ref_blend, out),
                                "ssim": ssim(ref_blend, out)}
        report(f"fig3,{name},{rows[f'fig3/{name}']['psnr_db']:.1f},"
               f"{rows[f'fig3/{name}']['ssim']:.4f}")
    if quick:
        return rows

    # Fig 4 caption: PSNR w.r.t. the original noise-free image — the
    # filter denoises; approximate arithmetic must not degrade the result.
    # Averaged over 3 images (the paper averages over the USC-SIPI set).
    cases = {k: [] for k in ("noisy", "accurate", "div-only/simdive",
                             "div-only/mitchell", "div-only/inzed-const",
                             "hybrid/simdive", "hybrid/mitchell")}
    for seed in (3, 4, 5):
        clean = synth_image(seed).astype(np.float64)
        rng = np.random.default_rng(seed + 100)
        noisy = np.clip(clean + rng.normal(scale=20.0, size=clean.shape),
                        0, 255)
        noisy_u = noisy.astype(np.uint32)
        crop = clean[2:-2, 2:-2]
        cases["noisy"].append(psnr(clean, noisy))
        cases["accurate"].append(psnr(crop, gaussian(
            noisy_u, muls["accurate"], divs["accurate"])))
        for name in ("simdive", "mitchell", "inzed-const"):
            cases[f"div-only/{name}"].append(psnr(crop, gaussian(
                noisy_u, muls["accurate"], divs[name])))
        for name in ("simdive", "mitchell"):
            cases[f"hybrid/{name}"].append(psnr(crop, gaussian(
                noisy_u, muls[name], divs[name])))
    report("fig4,design,PSNR-dB vs noise-free (paper: div-only simdive 24.5"
           " vs inzed 20.9; hybrid simdive 23.3 vs 21.3)")
    for k, v in cases.items():
        rows[f"fig4/{k}"] = {"psnr_db": float(np.mean(v))}
        report(f"fig4,{k},{np.mean(v):.1f}")
    return rows


if __name__ == "__main__":
    main()
