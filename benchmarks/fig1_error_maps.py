"""Fig. 1 reproduction: Mitchell error heat maps over the fraction square.

Dumps the 8x8 (and 16x16) region-mean relative-error maps for multiplier
and divider, before/after SIMDive correction, as CSV — the quantitative
content of the paper's Fig. 1 (b)/(e) plus the §3.3 observations:
  * error replicates across power-of-two intervals (checked numerically),
  * error is symmetric-ish along the anti-diagonal for mul,
  * correction flattens the map by ~5x.

Arithmetic dispatches through the kernel registry; per-lane relative
errors come from :mod:`repro.metrics`.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import SimdiveSpec, mitchell_mul
from repro.kernels import get_op
from repro.metrics import grid8, relative_error


def region_map(op, corrected, n=8, width=8, backend="ref"):
    A, B = grid8(flat=False)
    Aj, Bj = jnp.asarray(A.ravel()), jnp.asarray(B.ravel())
    spec = SimdiveSpec(width=width, coeff_bits=6 if corrected else 0,
                       round_output=corrected)
    bound = get_op("elemwise", spec, backend)
    if op == "mul":
        out = np.asarray(bound(Aj, Bj, op="mul")).astype(np.float64)
        true = A.ravel().astype(np.float64) * B.ravel().astype(np.float64)
    else:
        FO = 12
        out = np.asarray(bound(Aj, Bj, op="div", frac_out=FO)
                         ).astype(np.float64) / 2**FO
        true = A.ravel().astype(np.float64) / B.ravel().astype(np.float64)
    rel = relative_error(out, true)
    # fraction of each operand (position within its power-of-two interval)
    k1 = np.floor(np.log2(A.ravel())).astype(int)
    k2 = np.floor(np.log2(B.ravel())).astype(int)
    x1 = A.ravel() / (1 << k1) - 1.0
    x2 = B.ravel() / (1 << k2) - 1.0
    r1 = np.minimum((x1 * n).astype(int), n - 1)
    r2 = np.minimum((x2 * n).astype(int), n - 1)
    m = np.zeros((n, n))
    c = np.zeros((n, n))
    np.add.at(m, (r1, r2), rel)
    np.add.at(c, (r1, r2), 1)
    return m / np.maximum(c, 1)


def power_of_two_replication(op="mul"):
    """§3.3 point 2: per-interval error maps are (near-)identical."""
    A, B = grid8(flat=False)
    k1 = np.floor(np.log2(A)).astype(int)
    Aj, Bj = jnp.asarray(A.ravel()), jnp.asarray(B.ravel())
    p = np.asarray(mitchell_mul(Aj, Bj, 8)).astype(np.float64)
    rel = relative_error(p, A.astype(np.float64).ravel() * B.ravel()
                         ).reshape(A.shape)
    means = [rel[(k1 == k) & (B >= 16)].mean() for k in range(4, 8)]
    return float(np.std(means) / np.mean(means))


def main(report=print, quick=False):
    import os
    outdir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(outdir, exist_ok=True)
    rows = {}
    for op in ("mul", "div"):
        for corrected in (False, True):
            m = region_map(op, corrected)
            tag = f"fig1_{op}_{'simdive' if corrected else 'mitchell'}"
            np.savetxt(os.path.join(outdir, tag + ".csv"), m, delimiter=",",
                       fmt="%.5f")
            rows[tag] = {"mean_pct": 100 * float(m.mean()),
                         "max_region_pct": 100 * float(m.max())}
            report(f"fig1,{tag},mean={100*m.mean():.3f}%,max-region="
                   f"{100*m.max():.3f}%")
    cv = power_of_two_replication()
    rows["pow2-replication-cv"] = {"cv": cv}
    report(f"fig1,pow2-replication-cv,{cv:.4f},coefficient of variation of "
           "per-interval mean error (paper: identical across intervals)")
    return rows


if __name__ == "__main__":
    main()
