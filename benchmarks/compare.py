"""The trajectory regression gate, as a CLI.

Diffs a candidate BENCH run against the committed baseline trajectory and
exits non-zero on any regression (see :mod:`repro.metrics.trajectory` for
the classification rules and default thresholds).

Usage:
  python benchmarks/compare.py
      Gate the committed trajectory against itself: latest grid-bearing
      run vs the previous one. With fewer than two grid runs there is
      nothing to diff — the gate passes vacuously (a fresh clone must
      never fail CI).
  python benchmarks/compare.py --candidate fresh.json
      Gate a fresh run file (e.g. tier-2 CI's ``run.py --quick`` output,
      written to a scratch path) against the committed baseline. The
      latest grid-bearing run on each side is compared.
  python benchmarks/compare.py --self-test
      No sweeps, no files: run the gate over built-in fixtures and verify
      every class trips (and only then). Tier-1 CI runs this on every
      push so a compare.py breakage cannot hide until the nightly diff.

Exit codes: 0 pass · 1 regression (per-key report on stdout) · 2 the
trajectory itself could not be read.
"""
from __future__ import annotations

import argparse
import copy
import importlib.util
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_TRAJ_PATH = os.path.join(_REPO_ROOT, "src", "repro", "metrics",
                          "trajectory.py")
if __package__ in (None, ""):
    sys.path.insert(0, _REPO_ROOT)
    sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))


def _load_trajectory_module():
    """Load trajectory.py straight from its file, not via the package.

    ``repro.metrics.__init__`` eagerly imports the timing harness and with
    it jax; the gate must stay runnable on a box whose accelerator stack
    is broken (that being one of the failure modes it judges), so it takes
    the pure-stdlib module alone. Falls back to the package import when
    the source layout differs (e.g. an installed distribution).
    """
    name = "simdive_bench_trajectory"
    try:
        spec = importlib.util.spec_from_file_location(name, _TRAJ_PATH)
        mod = importlib.util.module_from_spec(spec)
        sys.modules[name] = mod      # dataclasses resolve via sys.modules
        try:
            spec.loader.exec_module(mod)
        # simdive-lint: allow(swallowed-exception): sys.modules cleanup only — re-raised
        except BaseException:
            sys.modules.pop(name, None)
            raise
        return mod
    except (OSError, ImportError, AttributeError):
        from repro.metrics import trajectory
        return trajectory


_traj = _load_trajectory_module()
SCHEMA_V1 = _traj.SCHEMA_V1
Thresholds = _traj.Thresholds
TrajectoryError = _traj.TrajectoryError
diff_runs = _traj.diff_runs
index_grid = _traj.index_grid
latest_grid_run = _traj.latest_grid_run
load_trajectory = _traj.load_trajectory
migrate_doc = _traj.migrate_doc

DEFAULT_BENCH = os.path.join(_REPO_ROOT, "BENCH_simdive.json")


# ------------------------------------------------------------- fixtures --
def fixture_entry(**over) -> dict:
    """One healthy v2 grid entry; keyword overrides patch any field.

    Shared with tests/test_trajectory.py — the gate's unit tests and its
    --self-test must agree on what a plausible record looks like.
    """
    entry = {
        "kernel": "elemwise", "op": "mul", "width": 8, "coeff_bits": 6,
        "index_bits": 3, "backend": "ref", "status": "ok",
        "n": 65025, "seed": 0, "exhaustive": True, "frac_out": 0,
        "error": {"n": 65025, "are_pct": 0.845, "mred": 0.00845,
                  "nmed": 0.0018, "pre_pct": 4.54, "wce": 1072.0,
                  "error_rate": 0.984},
        "throughput": {"mean_us": 900.0, "best_us": 850.0, "iters": 5,
                       "warmup": 1, "shape_buckets": [[65536], [65536]],
                       "items": 65025, "items_per_s": 7.2e7},
    }
    err = over.pop("error", None)
    tp = over.pop("throughput", None)
    entry.update(over)
    if err:
        entry["error"] = {**entry["error"], **err}
    if tp:
        entry["throughput"] = {**entry["throughput"], **tp}
    return entry


def fixture_v1_entry(**over) -> dict:
    """:func:`fixture_entry` as a v1 record — the fields v2 backfills
    (``kernel``/``status``) stripped. The one place the v1/v2 field delta
    is encoded for fixtures; tests derive v1 records from here too."""
    return {k: v for k, v in fixture_entry(**over).items()
            if k not in ("kernel", "status")}


def fixture_run(entries: list[dict] | None = None, **over) -> dict:
    """One v2 run record around ``entries`` (default: a 3-config grid
    spanning exhaustive/sampled/parity, the classes the gate treats
    differently)."""
    if entries is None:
        entries = [
            fixture_entry(),
            fixture_entry(op="div", width=16, exhaustive=False, n=250000,
                          frac_out=12,
                          error={"are_pct": 0.41, "mred": 0.0041},
                          throughput={"mean_us": 1500.0,
                                      "shape_buckets": [[262144], [262144]]}),
            fixture_entry(backend="pallas-interpret", exhaustive=False,
                          n=4096,
                          throughput={"mean_us": 4.0e6,
                                      "shape_buckets": [[4096], [4096]]}),
        ]
    run = {"created_unix": 0, "quick": True, "only": None, "seconds": 1.0,
           "jax": "0.0", "platform": "cpu", "failures": 0,
           "grid": entries, "suites": {}}
    run.update(over)
    return run


def _self_test() -> int:
    """Exercise every gate class on fixtures; 0 iff the gate behaves."""
    base = fixture_run()
    checks: list[tuple[str, bool, str]] = []

    def check(name: str, ok: bool, detail: str = "") -> None:
        checks.append((name, ok, detail))

    # identical runs pass clean
    r = diff_runs(base, copy.deepcopy(base))
    check("identical-pass", r.ok and r.compared == 3, r.render())

    # worsened exhaustive ARE% -> error-regression
    cand = copy.deepcopy(base)
    cand["grid"][0]["error"]["are_pct"] += 0.01
    r = diff_runs(base, cand)
    check("exhaustive-error-trips",
          not r.ok and [f.kind for f in r.failures] == ["error-regression"],
          r.render())

    # sampled config: small drift tolerated, big drift trips
    cand = copy.deepcopy(base)
    cand["grid"][1]["error"]["are_pct"] *= 1.01
    check("sampled-rtol-tolerated", diff_runs(base, cand).ok,
          diff_runs(base, cand).render())
    cand["grid"][1]["error"]["are_pct"] *= 1.10
    r = diff_runs(base, cand)
    check("sampled-error-trips",
          not r.ok and [f.kind for f in r.failures] == ["error-regression"],
          r.render())

    # >5% ref throughput drop trips; interpreter timing never does
    cand = copy.deepcopy(base)
    cand["grid"][0]["throughput"]["best_us"] *= 1.10
    cand["grid"][2]["throughput"]["best_us"] *= 50.0
    r = diff_runs(base, cand)
    check("ref-throughput-trips",
          not r.ok
          and [f.kind for f in r.failures] == ["throughput-regression"],
          r.render())

    # a per-config failure is a gate failure, distinct from 'missing'
    cand = copy.deepcopy(base)
    cand["grid"][0] = {k: v for k, v in cand["grid"][0].items()
                       if k != "error"}
    cand["grid"][0].update(status="failed", error_msg="XlaRuntimeError: boom")
    r = diff_runs(base, cand)
    check("config-failed-trips",
          not r.ok and [f.kind for f in r.failures] == ["config-failed"],
          r.render())

    # missing config: warning by default, failure under strict_missing
    cand = copy.deepcopy(base)
    del cand["grid"][0]
    r = diff_runs(base, cand)
    check("missing-warns", r.ok and any(f.kind == "config-missing"
                                        for f in r.findings), r.render())
    r = diff_runs(base, cand, Thresholds(strict_missing=True))
    check("missing-strict-fails",
          not r.ok and [f.kind for f in r.failures] == ["config-missing"],
          r.render())

    # v1 documents migrate and gate cleanly against v2 runs
    v1 = migrate_doc({"schema": SCHEMA_V1,
                      "runs": [{"grid": [fixture_v1_entry()]}]})
    r = diff_runs(v1["runs"][0], fixture_run(entries=[fixture_entry()]))
    check("v1-migration-compares", r.ok and r.compared == 1, r.render())

    # a brand-new config that already failed is a failure, not news
    cand = copy.deepcopy(base)
    cand["grid"].append({**fixture_entry(op="mixed"), "status": "failed",
                         "error_msg": "new and broken"})
    del cand["grid"][-1]["error"]
    r = diff_runs(base, cand)
    check("new-failed-config-trips",
          not r.ok and [f.kind for f in r.failures] == ["config-failed"],
          r.render())

    failed = [c for c in checks if not c[1]]
    for name, ok, detail in checks:
        print(f"self-test {'ok  ' if ok else 'FAIL'} {name}")
        if not ok and detail:
            print("  " + detail.replace("\n", "\n  "))
    print(f"self-test: {len(checks) - len(failed)}/{len(checks)} passed")
    return 1 if failed else 0


# ------------------------------------------------------------- speedup --
def speedup_report(baseline_run: dict, candidate_run: dict, *,
                   baseline_label: str = "baseline",
                   candidate_label: str = "candidate") -> str:
    """Per-key ``best_us`` ratio summary, rendered as a markdown table.

    Informational (never gates): the PR-description / CI-step-summary
    companion of the regression gate. Interpreter-backend keys are listed
    but marked — their wall-clock is a correctness artifact, not a speed
    claim. speedup = baseline / candidate (>1 means the candidate is
    faster).
    """
    base_ix = index_grid(baseline_run or {})
    cand_ix = index_grid(candidate_run or {})
    lines = [
        f"### best_us speedup: {candidate_label} vs {baseline_label}",
        "",
        "| config | baseline us | candidate us | speedup |",
        "|---|---:|---:|---:|",
    ]
    ratios = []
    for key in sorted(set(base_ix) & set(cand_ix), key=repr):
        base, cand = base_ix[key], cand_ix[key]
        if base.get("status") != "ok" or cand.get("status") != "ok":
            continue
        b = (base.get("throughput") or {}).get("best_us")
        c = (cand.get("throughput") or {}).get("best_us")
        if not isinstance(b, (int, float)) or not isinstance(c, (int, float)) \
                or b <= 0 or c <= 0:
            continue
        kernel, op, width, cb, ib, backend, buckets = key
        shape = "x".join("·".join(str(d) for d in bk) for bk in buckets)
        cfg = f"{kernel}/{op}/{width}b/cb{cb}/{backend}"
        if shape:
            cfg += f"/{shape}"
        note = " (interp)" if backend == "pallas-interpret" else ""
        ratio = b / c
        if not note:
            ratios.append(ratio)
        lines.append(f"| {cfg}{note} | {b:.0f} | {c:.0f} | {ratio:.2f}x |")
    if ratios:
        import math
        geo = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
        lines += ["",
                  f"geometric-mean speedup over {len(ratios)} "
                  f"non-interpreter key(s): **{geo:.2f}x**"]
    else:
        lines += ["", "no comparable keys"]
    return "\n".join(lines)


# ------------------------------------------------------------------ CLI --
def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff a BENCH run against the committed trajectory "
                    "and fail on regressions.")
    ap.add_argument("--baseline", default=DEFAULT_BENCH,
                    help="committed trajectory file (default: "
                         "BENCH_simdive.json)")
    ap.add_argument("--candidate", default=None,
                    help="fresh run file to gate; default: the baseline's "
                         "own latest grid run vs its previous one")
    ap.add_argument("--throughput-drop-pct", type=float,
                    default=Thresholds.throughput_drop_pct,
                    help="max tolerated %% slowdown on ref configs "
                         "(default %(default)s)")
    ap.add_argument("--error-rtol", type=float,
                    default=Thresholds.sampled_error_rtol,
                    help="relative error-stat headroom on sampled configs "
                         "(default %(default)s)")
    ap.add_argument("--strict-missing", action="store_true",
                    help="fail (not warn) when a baseline config is absent "
                         "from the candidate")
    ap.add_argument("--self-test", action="store_true",
                    help="no files: verify the gate trips on built-in "
                         "fixtures (tier-1 CI)")
    ap.add_argument("--speedup", action="store_true",
                    help="summary mode: print per-key best_us ratios vs "
                         "the baseline (markdown; informational, exit 0)")
    args = ap.parse_args(argv)

    if args.self_test:
        return _self_test()

    th = Thresholds(throughput_drop_pct=args.throughput_drop_pct,
                    sampled_error_rtol=args.error_rtol,
                    strict_missing=args.strict_missing)
    try:
        base_doc = load_trajectory(args.baseline)
        if args.candidate is not None:
            cand_doc = load_trajectory(args.candidate, missing_ok=False)
            cand = latest_grid_run(cand_doc)
            baseline = latest_grid_run(base_doc)
            cand_label = os.path.basename(args.candidate)
        else:
            # self-diff: the file's latest grid run against its own history
            runs = base_doc.get("runs", [])
            cand_i = next((i for i in range(len(runs) - 1, -1, -1)
                           if runs[i].get("grid")), None)
            cand = runs[cand_i] if cand_i is not None else None
            baseline = (latest_grid_run(base_doc, before=cand_i)
                        if cand_i is not None else None)
            cand_label = f"{os.path.basename(args.baseline)}[latest]"
    except TrajectoryError as e:
        print(f"trajectory gate: cannot read inputs: {e}")
        return 2

    if cand is None:
        print("trajectory gate: candidate has no grid-bearing run; "
              "nothing to gate (pass)")
        return 0
    if baseline is None:
        print("trajectory gate: no baseline grid run to diff against; "
              "nothing to gate (pass)")
        return 0

    if args.speedup:
        print(speedup_report(baseline, cand,
                             baseline_label=os.path.basename(args.baseline),
                             candidate_label=cand_label))
        return 0

    report = diff_runs(baseline, cand, th,
                       baseline_label=os.path.basename(args.baseline),
                       candidate_label=cand_label)
    print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
