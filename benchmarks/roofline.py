"""§Roofline report: three terms per (arch x shape) from the dry-run sweep.

Reads results/dryrun/<arch>__<shape>__singlepod*.json (produced by
``repro.launch.dryrun``; multipod cells prove lowering only) and renders:

  compute_s     = per-device HLO FLOPs / 197e12        (TPU v5e bf16 peak)
  memory_s      = per-device fused-HBM-bytes / 819e9
  collective_s  = per-device collective bytes / 50e9   (ICI, per link)
  bottleneck    = argmax of the three
  MODEL_FLOPS   = 6*N_active*tokens (train) / 2*N_active*tokens (serve)
  useful_ratio  = MODEL_FLOPS / (HLO_FLOPs * n_devices)   [catches waste]
  roofline_frac = ideal_compute_s / dominant_term_s
                  (fraction of the compute roofline the compiled program
                   would reach if every term overlapped perfectly)

``memory_s`` prices the VMEM-resident attention kernel (the registry's
``attention`` op — dryrun's v2 traffic model charges q/k/v chunk reads, not
the (qc, kc) score tiles the kernel keeps on-chip); the dryrun JSONs also
carry ``memory_s_noflash``, the serial pre-kernel XLA path that round-trips
every score tile through HBM. The report emits both as an arithmetic-
intensity comparison (flops / HBM bytes): the pipelined kernel's AI gain
over the serial path is exactly the traffic it keeps resident.

Outputs a CSV stream + results/roofline.md (the EXPERIMENTS.md table).
"""
from __future__ import annotations

import glob
import json
import os
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _active_param_fraction(path: str, cfg) -> float:
    """Fraction of this leaf's parameters that do matmul work per token."""
    if re.search(r"embed$", path):
        # embedding lookup is a gather, not FLOPs; tied embeddings are
        # counted at the head instead (same tensor, one matmul)
        return 1.0 if cfg.tie_embeddings else 0.0
    if re.search(r"moe/(w1|w2|w3)$", path):   # routed experts: top-k of E
        return cfg.n_experts_active / max(cfg.n_experts, 1)
    if re.search(r"(ln_|norm|router|u_bonus|lora_)", path):
        return 0.0                            # vector ops / tiny
    return 1.0


def _walk(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, f"{prefix}{k}/")
    else:
        yield prefix.rstrip("/"), tree


def active_params(arch: str) -> tuple[int, int]:
    """(total, matmul-active) parameter counts for an architecture."""
    import jax
    from repro.configs import get_config
    from repro.models import build

    cfg = get_config(arch)
    sds = jax.eval_shape(build(cfg).init, jax.random.PRNGKey(0))
    total = active = 0
    for path, leaf in _walk(sds):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        active += int(n * _active_param_fraction(path, cfg))
    return total, active


def model_flops(arch: str, shape_name: str, n_active: int) -> float:
    from repro.configs import SHAPES
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one new token per sequence per step
    return 2.0 * n_active * shape.global_batch


def memory_floor_bytes(arch: str, shape_name: str, n_total: int,
                       n_devices: int) -> float:
    """Per-device intrinsic HBM bytes for a step: weight stream + (decode)
    KV-cache read. Weights are TP-sharded (bf16 serve / f32+grad+opt train);
    the cache is sharded over batch (data) and seq-or-heads (model)."""
    from repro.configs import SHAPES, get_config
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    model_size = 16
    data_size = n_devices // model_size
    if shape.kind == "train":
        # fwd read + bwd read + grad write (bf16 compute copies) + opt pass
        return n_total * 2.0 * 3 / model_size + n_total * 12.0 / n_devices
    w = n_total * 2.0 / model_size          # bf16 weights, one stream
    if shape.kind == "prefill":
        return w
    if cfg.attn_free:
        state = (cfg.n_layers * shape.global_batch / data_size
                 * cfg.d_model * 2 * 2.0)
        return w + state
    S = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
    cache = (cfg.n_layers * (shape.global_batch / data_size) * S
             * cfg.n_kv_heads * cfg.d_head * 2 * 2.0 / model_size)
    return w + cache


_CELL_RE = re.compile(r"(?P<arch>.+)__(?P<shape>[a-z0-9_]+)__singlepod\.json$")


def load_cells(dirname=None):
    """Final-config cells from results/dryrun, falling back to the archived
    §Perf baseline (results/dryrun_baseline) for cells the final re-sweep
    has not (re)compiled — each row is tagged with its source config."""
    dirname = dirname or os.path.join(RESULTS, "dryrun")
    fallback = os.path.join(RESULTS, "dryrun_baseline")
    names = {}
    for path in sorted(glob.glob(os.path.join(fallback, "*__singlepod.json"))):
        names[os.path.basename(path)] = (path, "baseline")
    for path in sorted(glob.glob(os.path.join(dirname, "*__singlepod.json"))):
        names[os.path.basename(path)] = (path, "final")
    cells = []
    for base in sorted(names):
        path, cfg_tag = names[base]
        m = _CELL_RE.search(base)
        if not m:
            continue
        with open(path) as f:
            d = json.load(f)
        d["config"] = cfg_tag
        if d.get("status") != "ok" or "roofline" not in d:
            cells.append({"arch": m["arch"], "shape": m["shape"],
                          "status": d.get("status", "?"), "config": cfg_tag,
                          "error": d.get("error", "")[:100]})
            continue
        cells.append(d)
    return cells


def improvement_note(d, dom, ratio, n_params):
    if dom == "collective_s":
        coll = d["per_device"]["collective_bytes"]
        big = max(coll, key=coll.get)
        if n_params < 8e9 and d["shape"].endswith(("4k", "32k")):
            return (f"dominant collective {big}: model fits without TP — "
                    "rebind to pure DP + ZeRO-3 (--pure-dp; measured 21x on "
                    "qwen3-4b train)")
        return (f"dominant collective is {big}; reshard to keep that tensor "
                f"local (or overlap it with compute)")
    if dom == "memory_s":
        return ("HBM-bound: quantize the largest streaming tensors "
                "(int8 weights measured -14% on qwen2.5 decode; int8 KV "
                "next) or raise arithmetic intensity")
    if ratio < 0.5:
        return ("compute-bound but useful_ratio "
                f"{ratio:.2f} — remove redundant/replicated compute first")
    return "compute-bound near roofline: only faster math (int8/packing) helps"


def main(report=print):
    cells = load_cells()
    rows = []
    ai_rows = []
    n_active_cache: dict[str, tuple[int, int]] = {}
    report("roofline,arch,shape,cfg,compute_s,memory_s,collective_s,"
           "bottleneck,model_gflops,useful_ratio,roofline_frac")
    md = ["| arch | shape | cfg | compute_s | memory_s | collective_s | "
          "bottleneck | useful_ratio | roofline_frac | what would move it |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for d in cells:
        arch, shape = d["arch"], d["shape"]
        if "roofline" not in d:
            report(f"roofline,{arch},{shape},ERROR,{d.get('error','')}")
            md.append(f"| {arch} | {shape} | ERROR {d.get('error','')} | "
                      "| | | | | |")
            continue
        if arch not in n_active_cache:
            n_active_cache[arch] = active_params(arch)
        n_tot, n_act = n_active_cache[arch]
        r = d["roofline"]
        mf = model_flops(arch, shape, n_act)
        hlo_global = d["per_device"]["flops"] * d["n_devices"]
        ratio = mf / hlo_global if hlo_global else 0.0
        # the binding ideal is the LARGER of the compute ideal and the
        # intrinsic memory floor (decode/prefill are HBM-bound by design)
        ideal_s = max(mf / d["n_devices"] / PEAK_FLOPS,
                      memory_floor_bytes(arch, shape, n_tot,
                                         d["n_devices"]) / HBM_BW)
        dom = r["bottleneck"]
        dom_s = r[dom]
        frac = ideal_s / dom_s if dom_s else 0.0
        note = improvement_note(d, dom, ratio, n_tot)
        cfg_tag = d.get("config", "final")
        report(f"roofline,{arch},{shape},{cfg_tag},{r['compute_s']:.4g},"
               f"{r['memory_s']:.4g},{r['collective_s']:.4g},{dom},"
               f"{mf/1e9:.4g},{ratio:.3f},{frac:.3f}")
        md.append(f"| {arch} | {shape} | {cfg_tag} | {r['compute_s']:.4g} | "
                  f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | {dom} | "
                  f"{ratio:.3f} | {frac:.3f} | {note} |")
        rows.append((arch, shape, frac, dom))
        # pipelined (VMEM-resident kernel) vs serial (pre-kernel XLA)
        # arithmetic intensity — only for cells whose dryrun carries both
        flops = d["per_device"].get("flops")
        kb = d["per_device"].get("bytes_accessed")
        sb = d["per_device"].get("bytes_accessed_noflash")
        if flops and kb and sb:
            ai_rows.append((arch, shape, flops / sb, flops / kb, sb / kb))
    if ai_rows:
        md += ["", "## Pipelined kernel vs serial attention traffic", "",
               "Arithmetic intensity (HLO flops / HBM bytes): `serial` "
               "round-trips every (qc, kc) score tile through HBM "
               "(pre-kernel XLA path); `kernel` is the VMEM-resident "
               "flash kernel with the double-buffered kv sweep — the "
               "traffic ratio is the tile traffic the pipeline keeps "
               "on-chip.", "",
               "| arch | shape | AI serial | AI kernel | traffic ratio |",
               "|---|---|---|---|---|"]
        for arch, shape, ai_s, ai_k, ratio in ai_rows:
            report(f"roofline-ai,{arch},{shape},{ai_s:.4g},{ai_k:.4g},"
                   f"{ratio:.2f}x")
            md.append(f"| {arch} | {shape} | {ai_s:.4g} | {ai_k:.4g} | "
                      f"{ratio:.2f}x |")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    if rows:
        worst = min(rows, key=lambda t: t[2])
        report(f"roofline,worst-cell,{worst[0]},{worst[1]},frac={worst[2]:.3f}"
               f",{worst[3]}")
    report(f"roofline,cells,{len(rows)},ok")


if __name__ == "__main__":
    main()
