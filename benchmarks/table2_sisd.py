"""Table 2 reproduction: SISD multiplier/divider error metrics.

Paper columns we CAN reproduce bit-exactly off-FPGA: ARE% / PRE% for every
design. Area/delay/power/energy are Vivado synthesis artifacts; their
TPU-meaningful analogue here is the op/traffic profile (#adds+shifts vs
#multiplies per scalar op, table bytes).

Competitor models (same taxonomy as the paper; implementations shared with
Fig. 3/4 via :mod:`repro.core.baselines`):
  accurate        — exact integer multiply / divide
  trunc7 / trunc15— truncated multipliers (top-k bits, round)
  mitchell        — plain Mitchell [22]
  mbm             — Mitchell + single constant correction (SoA MBM [28])
  inzed           — divider with single constant correction (INZeD [29])
  simdive         — ours: 64-region table + rounding (coeff_bits=6)
  simdive-alm     — §3.4 variant: 256 regions (8-input-LUT devices)

SIMDive rows dispatch through the kernel registry (``get_op("elemwise")``),
the same entry point models and kernels use; error statistics come from
:mod:`repro.metrics`. All samplers take explicit seeds so row values are
reproducible run-to-run (the BENCH trajectory depends on this).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import SimdiveSpec, mitchell_div, mitchell_mul
from repro.core.baselines import const_corr_op, trunc_mul
from repro.kernels import get_op
from repro.metrics import DIV_FRAC_OUT as FRAC_OUT
from repro.metrics import error_stats, grid8, sample_uints


def _grid8():
    """Exhaustive nonzero 8-bit operand grid (255 x 255 pairs)."""
    A, B = grid8()
    return jnp.asarray(A), jnp.asarray(B)


def _sample16(n=250_000, seed=0, div_width=16):
    a, b = sample_uints(16, n, seed, b_width=div_width)
    return jnp.asarray(a), jnp.asarray(b)


def _simdive(op, spec, backend="ref"):
    """SIMDive row through the one registry entry point."""
    bound = get_op("elemwise", spec, backend)
    if op == "mul":
        return lambda a, b: np.asarray(bound(a, b, op="mul")).astype(np.float64)
    return lambda a, b: np.asarray(
        bound(a, b, op="div", frac_out=FRAC_OUT)).astype(np.float64) / 2**FRAC_OUT


def run(width=8, seed=0, backend="ref"):
    """All Table 2 rows at one width -> [(op, design, ErrorStats), ...]."""
    A, B = _grid8() if width == 8 else _sample16(seed=seed)
    # the paper's divider format is 16/8 (8-bit divisor): keeps the
    # quotient above the frac_out quantization floor
    Ad, Bd = (A, B) if width == 8 else _sample16(seed=seed, div_width=8)
    t = np.asarray(A, np.float64) * np.asarray(B, np.float64)
    tq = np.asarray(Ad, np.float64) / np.asarray(Bd, np.float64)
    rows = []

    muls = {
        "accurate": lambda a, b: np.asarray(a, np.float64) * np.asarray(b, np.float64),
        f"trunc{min(7, width - 1)}": lambda a, b: np.asarray(
            trunc_mul(a, b, width, 7)).astype(np.float64),
        "trunc15": lambda a, b: np.asarray(
            trunc_mul(a, b, width, 15)).astype(np.float64),
        "mitchell": lambda a, b: np.asarray(
            mitchell_mul(a, b, width)).astype(np.float64),
        "mbm-const": lambda a, b: np.asarray(
            const_corr_op("mul", width)(a, b)).astype(np.float64),
        "simdive": _simdive("mul", SimdiveSpec(width=width, coeff_bits=6),
                            backend),
        "simdive-alm": _simdive(
            "mul", SimdiveSpec(width=width, coeff_bits=8, index_bits=4),
            backend),
    }
    for name, f in muls.items():
        rows.append(("mul", name, error_stats(f(A, B), t)))

    divs = {
        "accurate": lambda a, b: np.asarray(a, np.float64) / np.asarray(b, np.float64),
        "mitchell": lambda a, b: np.asarray(
            mitchell_div(a, b, width, frac_out=FRAC_OUT)
        ).astype(np.float64) / 2**FRAC_OUT,
        "inzed-const": lambda a, b: np.asarray(const_corr_op("div", width)(
            a, b, FRAC_OUT)).astype(np.float64) / 2**FRAC_OUT,
        "simdive": _simdive("div", SimdiveSpec(width=width, coeff_bits=6),
                            backend),
        "simdive-alm": _simdive(
            "div", SimdiveSpec(width=width, coeff_bits=8, index_bits=4),
            backend),
    }
    for name, f in divs.items():
        rows.append(("div", name, error_stats(np.maximum(f(Ad, Bd), 1e-12), tq)))
    return rows


def main(report=print, quick=False):
    rows = run(8, seed=0)
    report("op,design,ARE%,PRE%   (8-bit exhaustive; paper Table 2 anchors:"
           " mitchell 3.85/11.11, simdive 0.82/4.9 | div 4.11/13, 0.77/5.24)")
    for op, name, s in rows:
        report(f"table2,{op}/{name},{s.are_pct:.3f},{s.pre_pct:.2f}")
    if quick:
        return {"table2": rows}
    rows16 = run(16, seed=0)
    for op, name, s in rows16:
        report(f"table2_16b,{op}/{name},{s.are_pct:.3f},{s.pre_pct:.2f}")
    return {"table2": rows, "table2_16b": rows16}


if __name__ == "__main__":
    main()
