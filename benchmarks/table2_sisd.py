"""Table 2 reproduction: SISD multiplier/divider error metrics.

Paper columns we CAN reproduce bit-exactly off-FPGA: ARE% / PRE% for every
design. Area/delay/power/energy are Vivado synthesis artifacts; their
TPU-meaningful analogue here is the op/traffic profile (#adds+shifts vs
#multiplies per scalar op, table bytes).

Competitor models (same taxonomy as the paper):
  accurate        — exact integer multiply / divide
  trunc7 / trunc15— truncated multipliers (top-k bits, round)
  mitchell        — plain Mitchell [22]
  mbm             — Mitchell + single constant correction (SoA MBM [28])
  inzed           — divider with single constant correction (INZeD [29])
  simdive         — ours: 64-region table + rounding (coeff_bits=6)
  simdive-alm     — §3.4 variant: 256 regions (8-input-LUT devices)

Expected anchors (paper Table 2): mitchell 3.85/11.11, simdive 0.82/4.9 mul;
mitchell-div 4.11/~13, simdive-div 0.77/5.24.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import SimdiveSpec, mitchell_div, mitchell_mul, simdive_div, simdive_mul
from repro.core.error_lut import ideal_correction_div, ideal_correction_mul
from repro.core.mitchell import frac_bits, mitchell_antilog_div, mitchell_antilog_mul, mitchell_log, work_dtype


def _grid8():
    a = np.arange(1, 256, dtype=np.uint32)
    A, B = np.meshgrid(a, a, indexing="ij")
    return jnp.asarray(A.ravel()), jnp.asarray(B.ravel())


def _sample16(n=250_000, seed=0, div_width=16):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.integers(1, 1 << 16, n, dtype=np.uint32)),
            jnp.asarray(r.integers(1, 1 << div_width, n, dtype=np.uint32)))


def trunc_mul(a, b, width, keep):
    """Truncated multiplier: multiply the top-``keep`` bits exactly."""
    from repro.core.mitchell import leading_one
    dt = work_dtype(width)
    au, bu = a.astype(dt), b.astype(dt)
    ka = leading_one(au, width).astype(jnp.int32)
    kb = leading_one(bu, width).astype(jnp.int32)
    sa = jnp.maximum(ka - (keep - 1), 0)
    sb = jnp.maximum(kb - (keep - 1), 0)
    ah = (au >> sa.astype(dt))
    bh = (bu >> sb.astype(dt))
    return (ah * bh) << (sa + sb).astype(dt)


def _const_corr_op(op, width):
    """Single-constant correction (MBM/INZeD style)."""
    g = (np.arange(512) + 0.5) / 512
    X1, X2 = np.meshgrid(g, g, indexing="ij")
    f = ideal_correction_mul if op == "mul" else ideal_correction_div
    c = float(f(X1, X2).mean())
    F = frac_bits(width)
    cc = jnp.asarray(int(round(c * (1 << F))), jnp.int32)

    def mul(a, b):
        dt = work_dtype(width)
        au, bu = a.astype(dt), b.astype(dt)
        la, lb = mitchell_log(au, width), mitchell_log(bu, width)
        p = mitchell_antilog_mul(la, lb, width, corr=jnp.broadcast_to(cc, la.shape))
        return jnp.where((au == 0) | (bu == 0), jnp.zeros_like(p), p)

    def div(a, b, frac_out):
        dt = work_dtype(width)
        au, bu = a.astype(dt), b.astype(dt)
        la, lb = mitchell_log(au, width), mitchell_log(bu, width)
        q = mitchell_antilog_div(la, lb, width,
                                 corr=jnp.broadcast_to(cc, la.shape),
                                 frac_out=frac_out)
        return jnp.where(au == 0, jnp.zeros_like(q), q)

    return mul if op == "mul" else div


def _stats(approx, true):
    re = np.abs(approx - true) / true
    return 100 * re.mean(), 100 * re.max()


def run(width=8):
    A, B = _grid8() if width == 8 else _sample16()
    # the paper's divider format is 16/8 (8-bit divisor): keeps the
    # quotient above the frac_out quantization floor
    Ad, Bd = (A, B) if width == 8 else _sample16(div_width=8)
    t = np.asarray(A, np.float64) * np.asarray(B, np.float64)
    tq = np.asarray(Ad, np.float64) / np.asarray(Bd, np.float64)
    FO = 12
    rows = []

    # multipliers
    muls = {
        "accurate": lambda a, b: np.asarray(a, np.float64) * np.asarray(b, np.float64),
        f"trunc{min(7, width-1)}": lambda a, b: np.asarray(
            trunc_mul(a, b, width, 7)).astype(np.float64),
        "trunc15": lambda a, b: np.asarray(
            trunc_mul(a, b, width, 15)).astype(np.float64),
        "mitchell": lambda a, b: np.asarray(
            mitchell_mul(a, b, width)).astype(np.float64),
        "mbm-const": lambda a, b: np.asarray(
            _const_corr_op("mul", width)(a, b)).astype(np.float64),
        "simdive": lambda a, b: np.asarray(simdive_mul(
            a, b, SimdiveSpec(width=width, coeff_bits=6))).astype(np.float64),
        "simdive-alm": lambda a, b: np.asarray(simdive_mul(
            a, b, SimdiveSpec(width=width, coeff_bits=8, index_bits=4)
        )).astype(np.float64),
    }
    for name, f in muls.items():
        are, pre = _stats(f(A, B), t)
        rows.append(("mul", name, are, pre))

    divs = {
        "accurate": lambda a, b: np.asarray(a, np.float64) / np.asarray(b, np.float64),
        "mitchell": lambda a, b: np.asarray(
            mitchell_div(a, b, width, frac_out=FO)).astype(np.float64) / 2**FO,
        "inzed-const": lambda a, b: np.asarray(_const_corr_op("div", width)(
            a, b, FO)).astype(np.float64) / 2**FO,
        "simdive": lambda a, b: np.asarray(simdive_div(
            a, b, SimdiveSpec(width=width, coeff_bits=6), frac_out=FO
        )).astype(np.float64) / 2**FO,
        "simdive-alm": lambda a, b: np.asarray(simdive_div(
            a, b, SimdiveSpec(width=width, coeff_bits=8, index_bits=4),
            frac_out=FO)).astype(np.float64) / 2**FO,
    }
    for name, f in divs.items():
        are, pre = _stats(np.maximum(f(Ad, Bd), 1e-12), tq)
        rows.append(("div", name, are, pre))
    return rows


def main(report=print):
    rows = run(8)
    report("op,design,ARE%,PRE%   (8-bit exhaustive; paper Table 2 anchors:"
           " mitchell 3.85/11.11, simdive 0.82/4.9 | div 4.11/13, 0.77/5.24)")
    for op, name, are, pre in rows:
        report(f"table2,{op}/{name},{are:.3f},{pre:.2f}")
    rows16 = run(16)
    for op, name, are, pre in rows16:
        report(f"table2_16b,{op}/{name},{are:.3f},{pre:.2f}")


if __name__ == "__main__":
    main()
